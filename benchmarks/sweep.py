"""Scenario sweep harness for the dynamic WAN simulator.

Runs the four methods (diloco / streaming / cocodc / local) across a grid of
network scenarios — generated N-region meshes (ring / hub_spoke / continental /
random_geo) with time-varying link dynamics (diurnal troughs, hub failures,
flaky crossings, jitter) — and emits one JSON per scenario under
``experiments/sweep/`` plus a cross-scenario summary. This is the stress rig
the adaptive transmission strategy (Eq. 11/12) was designed for: static
topologies never exercise it.

    PYTHONPATH=src python benchmarks/sweep.py                 # full grid
    PYTHONPATH=src python benchmarks/sweep.py --scenario hub_failure8
    PYTHONPATH=src python benchmarks/sweep.py --smoke         # CI: tiny grid
                                                              # + routed compare

Per (scenario, method) the JSON records steps-to-target-PPL (target = the
weakest method's best PPL, the Table-I analog), WAN bytes/busy-seconds per
link, stall seconds/fraction (time lost to troughs+outages vs the static
cost), outage retries, and the full eval history. The ``*_routed`` scenarios
rerun a dynamic scenario with the routed communication planner (multi-hop
routes + hub failover + Eq. 9 re-derivation); ``--smoke`` fails (exit 1) on
schema drift, non-finite metrics, or a routed hub-failure run whose stall
fraction is not strictly below its static-route twin's.

Bandwidth scales are AUTO-CALIBRATED from the sweep model's mean fragment
byte size (`calibrate_bw_scale`, paper_network-style): one fragment
collective spends ~CALIB_BW_STEPS compute steps in bandwidth, so the toy
transfers are bandwidth-dominated and the dynamics under test actually bite.
`Scenario.bw_scale` overrides the calibration when set.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import math
import os
import sys

if __package__ in (None, ""):                     # `python benchmarks/sweep.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import Timer, emit, save_json

from repro.configs import CoCoDCConfig
from repro.configs.base import ModelConfig
from repro.core.network import apply_dynamics, generate_mesh, make_scenario
from repro.core.trainer import CrossRegionTrainer, TrainerConfig

MODEL = ModelConfig(name="sweep-lm", family="dense", n_layers=4, d_model=96,
                    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                    compute_dtype="float32")

METHODS = ("diloco", "streaming", "cocodc", "local")
NUM_FRAGMENTS = 4
# auto-calibration target: bandwidth-seconds of one MEAN-FRAGMENT collective,
# in compute steps (latency is left untouched, so the calibrated transfers are
# bandwidth-dominated by construction — asserted in calibrate_bw_scale)
CALIB_BW_STEPS = 6.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One network condition: a base topology (generated mesh or named
    scenario; None = the calibrated symmetric paper network) plus an optional
    dynamics spec, at a given region count and step budget.

    `bw_scale` shrinks the mesh's real-world bandwidths so one fragment
    all-reduce costs several compute steps at this benchmark's tiny model
    scale (the same calibration trick as `paper_network`): without it the
    transfers are latency-dominated and diurnal troughs/outages would be
    invisible to the methods under test. ``None`` (the default) derives the
    scale from the sweep model's actual fragment byte size
    (`calibrate_bw_scale`); a float overrides the calibration."""
    name: str
    n: int = 4
    mesh: str | None = None          # generated-mesh profile
    topology: str | None = None      # named fixed scenario
    dynamics: str | None = None
    seed: int = 0
    steps: int = 96
    bw_scale: float | None = None    # None = auto-calibrate
    routing: str = "static"          # routed communication plans
    hub_failover: bool = False       # re-elect the hub while its links are out
    adaptive_resync: bool = False    # re-derive Eq. 9's N from measured T_s
    note: str = ""


# The grid: static anchor, the three dynamic failure modes the ROADMAP asks
# for (diurnal trough, hub failure, flaky transpacific), generated meshes at
# N in {4, 8, 16}, and routed-planner compares (`*_routed` runs the identical
# network with routing + hub failover + Eq. 9 re-derivation enabled).
# `n8_geo_diurnal_hub` is the acceptance scenario: an N=8 generated mesh under
# diurnal bandwidth AND a hub failure.
SCENARIOS = [
    Scenario("static4_paper", steps=96,
             note="static calibrated symmetric network — regression anchor"),
    Scenario("diurnal_trough4", topology="asym4", steps=96,
             dynamics="diurnal:period=96:depth=0.7",
             note="asym 4-region mesh through a deep synchronized trough"),
    Scenario("transpacific_flaky_dyn4", topology="transpacific_flaky",
             steps=96,
             dynamics="flaky:n=4:dur=6:factor=0.15,jitter:frac=0.05",
             note="degraded crossing + random flaky windows + jitter"),
    Scenario("hub_failure8", n=8, mesh="hub_spoke", steps=64,
             dynamics="hub_failure:start=24:dur=16",
             note="hierarchical mesh loses its hub mid-run (full outage)"),
    Scenario("hub_failure8_routed", n=8, mesh="hub_spoke", steps=64,
             dynamics="hub_failure:start=24:dur=16",
             routing="routed", hub_failover=True, adaptive_resync=True,
             note="hub_failure8 on the routed planner: the collective "
                  "re-forms around a deterministically elected stand-in hub"),
    Scenario("n8_geo_diurnal_hub", n=8, mesh="random_geo", steps=64,
             dynamics="diurnal:period=64:depth=0.6,"
                      "hub_failure:start=20:dur=12:factor=0.1",
             note="ACCEPTANCE: N=8 generated mesh, diurnal + hub failure"),
    Scenario("n8_geo_diurnal_hub_routed", n=8, mesh="random_geo", steps=64,
             dynamics="diurnal:period=64:depth=0.6,"
                      "hub_failure:start=20:dur=12:factor=0.1",
             routing="routed", hub_failover=True, adaptive_resync=True,
             note="acceptance compare: routed multi-hop planner on the same "
                  "N=8 geo mesh"),
    Scenario("continental8_jitter", n=8, mesh="continental", steps=64,
             dynamics="jitter:frac=0.1",
             note="clustered continents with per-transfer jitter"),
    Scenario("ring16_diurnal", n=16, mesh="ring", steps=48,
             dynamics="diurnal:period=48:depth=0.5",
             note="wide 16-region ring under staggered timezones"),
]

SMOKE_METHODS = ("streaming", "cocodc")
# smoke grid: (scenario name, methods, steps). The hub-failure pair runs long
# enough to cover the outage window [24, 40) AND recovery, because the smoke
# contract compares routed vs static stall fractions across it.
SMOKE_GRID = (
    ("static4_paper", SMOKE_METHODS, 12),
    ("n8_geo_diurnal_hub", SMOKE_METHODS, 12),
    ("hub_failure8", ("cocodc",), 44),
    ("hub_failure8_routed", ("cocodc",), 44),
)
# routed scenario -> its static-route twin; --smoke FAILS if the routed run's
# stall_fraction is not strictly below the static run's on any shared method
ROUTED_COMPARE = {
    "hub_failure8_routed": "hub_failure8",
    "n8_geo_diurnal_hub_routed": "n8_geo_diurnal_hub",
}

# Required result schema per (scenario, method) — drift fails --smoke.
RUN_SCHEMA = {
    "final_ppl": float, "final_nll": float, "steps_to_target": (int, type(None)),
    "host_s": float, "history": list, "stats": dict, "link_stats": dict,
}
STATS_KEYS = ("wall_clock_s", "comm_seconds", "bytes_sent", "n_syncs",
              "overlap_ratio", "stall_seconds", "stall_fraction", "n_retries",
              "reroutes", "hub_elections",
              "busiest_link_bytes", "busiest_link_seconds")


@functools.lru_cache(maxsize=1)
def fragment_wire_bytes() -> int:
    """Mean fragment payload of the sweep model (f32 wire format), from the
    real fragmenter — the calibration input."""
    import jax

    from repro.core.fragments import make_fragmenter
    from repro.models import api

    shape = jax.eval_shape(functools.partial(api.init_params, MODEL),
                           jax.random.PRNGKey(0))
    frag = make_fragmenter(MODEL, shape, NUM_FRAGMENTS)
    return frag.total_bytes // NUM_FRAGMENTS


def calibrate_bw_scale(net, frag_bytes: int, *,
                       target_steps: float = CALIB_BW_STEPS) -> float:
    """paper_network-style auto-calibration: the bandwidth multiplier that
    makes one mean-fragment collective spend `target_steps * T_c` seconds in
    its BANDWIDTH phase on this topology. The bandwidth phase is measured on
    a latency-free copy (on a heterogeneous mesh the collective's bottleneck
    link CHANGES with the scale, so subtracting the latency phases from the
    full cost would calibrate against the wrong link). Latencies are
    untouched, so the calibrated transfer is bandwidth-dominated — asserted,
    because a latency-dominated transfer would hide the dynamics under
    test."""
    import numpy as np
    lat_free = dataclasses.replace(net,
                                   latency_s=np.zeros_like(net.latency_s))
    bw_seconds = lat_free.allreduce_time(frag_bytes)
    if bw_seconds <= 0.0:
        raise AssertionError(
            f"calibration: topology has no bandwidth cost "
            f"({net.num_workers} regions)")
    target = target_steps * net.step_time_s
    lat = net.allreduce_time(0)
    assert target > lat, (
        f"calibrated transfer would be latency-dominated: bandwidth target "
        f"{target:.3f}s <= latency phases {lat:.3f}s")
    return bw_seconds / target


def build_network(sc: Scenario, step_time_s: float = 1.0):
    """None = let the trainer build the calibrated symmetric paper network."""
    if sc.mesh is not None:
        net = generate_mesh(sc.n, sc.mesh, seed=sc.seed,
                            step_time_s=step_time_s)
    elif sc.topology is not None:
        net = make_scenario(sc.topology, num_workers=sc.n,
                            step_time_s=step_time_s)
    else:
        return None
    scale = sc.bw_scale
    if scale is None:
        scale = calibrate_bw_scale(net, fragment_wire_bytes())
    if scale != 1.0:
        net = dataclasses.replace(net,
                                  bandwidth_Bps=net.bandwidth_Bps * scale)
    return apply_dynamics(net, sc.dynamics, seed=sc.seed)


def run_one(sc: Scenario, method: str, steps: int) -> dict:
    ccfg = CoCoDCConfig(num_workers=sc.n, local_steps=24,
                        num_fragments=NUM_FRAGMENTS,
                        overlap_depth=8, comp_lambda=0.5, net_utilization=0.4,
                        mixing_alpha=0.5, routing=sc.routing,
                        hub_failover=sc.hub_failover,
                        adaptive_resync=sc.adaptive_resync)
    tcfg = TrainerConfig(method=method, local_batch=4, seq_len=32,
                         total_steps=steps, warmup_steps=max(2, steps // 10),
                         inner_lr=3e-3, seed=sc.seed, eval_batch=8,
                         noniid_frac=0.3)
    net = build_network(sc)
    # dynamics on the default paper network go through the trainer hook
    dynamics = sc.dynamics if net is None else None
    tr = CrossRegionTrainer(MODEL, ccfg, tcfg, network=net,
                            dynamics=dynamics, dynamics_seed=sc.seed)
    with Timer() as t:
        hist = tr.run(eval_every=max(4, steps // 6), log=lambda s: None)
    final = hist[-1]
    return {"final_ppl": float(final["ppl"]), "final_nll": float(final["nll"]),
            "steps_to_target": None,     # filled once the target is known
            "host_s": t.dt, "history": hist, "stats": tr.engine.stats(),
            "link_stats": tr.engine.link_stats()}


def steps_to_ppl(hist, target):
    for rec in hist:
        if rec["ppl"] <= target:
            return rec["step"]
    return None


def run_scenario(sc: Scenario, methods=METHODS, steps: int | None = None) -> dict:
    steps = steps or sc.steps
    runs = {}
    for method in methods:
        r = run_one(sc, method, steps)
        runs[method] = r
        emit(f"sweep/{sc.name}/{method}", r["host_s"] * 1e6 / steps,
             f"final_ppl={r['final_ppl']:.2f};"
             f"wall={r['stats']['wall_clock_s']:.0f}s;"
             f"stall={r['stats']['stall_fraction']*100:.0f}%;"
             f"retries={int(r['stats']['n_retries'])}")
    # Table-I analog target: the weakest method's best-so-far PPL, so every
    # method is guaranteed to reach it within the run
    target = max(min(rec["ppl"] for rec in r["history"])
                 for r in runs.values())
    for method, r in runs.items():
        r["steps_to_target"] = steps_to_ppl(r["history"], target)
    payload = {"scenario": dataclasses.asdict(sc), "steps": steps,
               "target_ppl": target, "runs": runs}
    return payload


def validate_payload(payload: dict, scenario: str):
    """Schema + sanity guard for one scenario payload (CI --smoke contract):
    required keys with the right types, finite metrics, non-empty link stats,
    and dynamics actually exercised when the scenario declares any."""
    def fail(msg):
        raise AssertionError(f"[{scenario}] {msg}")

    for key in ("scenario", "steps", "target_ppl", "runs"):
        if key not in payload:
            fail(f"missing top-level key {key!r}")
    if not math.isfinite(payload["target_ppl"]):
        fail(f"target_ppl not finite: {payload['target_ppl']}")
    for method, r in payload["runs"].items():
        for key, typ in RUN_SCHEMA.items():
            if key not in r:
                fail(f"{method}: missing run key {key!r}")
            if not isinstance(r[key], typ):
                fail(f"{method}: {key} has type {type(r[key]).__name__}, "
                     f"want {typ}")
        for key in ("final_ppl", "final_nll"):
            if not math.isfinite(r[key]):
                fail(f"{method}: {key} is not finite ({r[key]})")
        for key in STATS_KEYS:
            if key not in r["stats"]:
                fail(f"{method}: stats missing {key!r}")
            if not math.isfinite(float(r["stats"][key])):
                fail(f"{method}: stats[{key}] not finite")
        for rec in r["history"]:
            if not math.isfinite(rec["nll"]):
                fail(f"{method}: NaN/inf eval nll at step {rec['step']}")
        if method != "local" and not r["link_stats"]["links"]:
            fail(f"{method}: no per-link WAN traffic recorded")
    dyn = payload["scenario"].get("dynamics")
    if dyn and "cocodc" in payload["runs"]:
        stalled = any(r["stats"]["stall_seconds"] > 0 or
                      r["stats"]["n_retries"] > 0
                      for m, r in payload["runs"].items() if m != "local")
        if not stalled and ("hub_failure" in dyn or "diurnal" in dyn):
            fail("dynamics declared but no run recorded any stall/retry")


def compare_routed(payloads: dict) -> "list[str]":
    """Routed-vs-static stall comparison over `ROUTED_COMPARE` pairs present
    in `payloads` (scenario name -> payload). Returns failure strings for any
    shared method where the routed run's stall_fraction is NOT strictly below
    the static-route run's — the failover acceptance contract."""
    failures = []
    for routed_name, static_name in ROUTED_COMPARE.items():
        rp, sp = payloads.get(routed_name), payloads.get(static_name)
        if rp is None or sp is None:
            continue
        shared = [m for m in rp["runs"] if m in sp["runs"] and m != "local"]
        for m in shared:
            rf = rp["runs"][m]["stats"]["stall_fraction"]
            sf = sp["runs"][m]["stats"]["stall_fraction"]
            st = rp["runs"][m]["stats"]
            emit(f"sweep/{routed_name}/{m}/stall_vs_static", 0.0,
                 f"routed={rf*100:.1f}%;static={sf*100:.1f}%;"
                 f"reroutes={int(st['reroutes'])};"
                 f"hub_elections={int(st['hub_elections'])}")
            if rf >= sf:
                failures.append(
                    f"[{routed_name}] {m}: routed stall_fraction {rf:.4f} is "
                    f"not strictly below static {sf:.4f}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default=None,
                    choices=[s.name for s in SCENARIOS],
                    help="run a single scenario from the grid")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the per-scenario step budget")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny grid incl. the routed hub-failure "
                         "compare; exits 1 on schema drift, NaN metrics, or a "
                         "routed run that does not beat its static twin's "
                         "stall fraction")
    args = ap.parse_args(argv)

    by_name = {s.name: s for s in SCENARIOS}
    if args.smoke:
        # --steps may shorten the quick scenarios but never the routed-vs-
        # static pair below its grid budget: cutting the run before the
        # outage window would fail the strict stall comparison spuriously
        compare_names = set(ROUTED_COMPARE) | set(ROUTED_COMPARE.values())
        grid = [(by_name[name], methods,
                 max(args.steps, steps) if args.steps and name
                 in compare_names else (args.steps or steps))
                for name, methods, steps in SMOKE_GRID]
    else:
        names = [args.scenario] if args.scenario else [s.name
                                                       for s in SCENARIOS]
        grid = [(by_name[n], METHODS, args.steps) for n in names]

    summary = {}
    failures = []
    payloads = {}
    for sc, methods, steps in grid:
        payload = run_scenario(sc, methods=methods, steps=steps)
        payloads[sc.name] = payload
        try:
            validate_payload(payload, sc.name)
        except AssertionError as e:
            failures.append(str(e))
            print(f"SCHEMA FAIL {e}", file=sys.stderr, flush=True)
        save_json(os.path.join("sweep", sc.name), payload)
        summary[sc.name] = {
            "note": sc.note, "n": sc.n, "steps": payload["steps"],
            "routing": sc.routing,
            "target_ppl": payload["target_ppl"],
            "steps_to_target": {m: r["steps_to_target"]
                                for m, r in payload["runs"].items()},
            "stall_fraction": {m: r["stats"]["stall_fraction"]
                               for m, r in payload["runs"].items()},
            "wall_clock_s": {m: r["stats"]["wall_clock_s"]
                             for m, r in payload["runs"].items()},
            "reroutes": {m: r["stats"]["reroutes"]
                         for m, r in payload["runs"].items()},
            "hub_elections": {m: r["stats"]["hub_elections"]
                              for m, r in payload["runs"].items()},
        }
        stt = summary[sc.name]["steps_to_target"]
        if stt.get("cocodc") and stt.get("streaming"):
            emit(f"sweep/{sc.name}/cocodc_vs_streaming", 0.0,
                 f"{100 * (1 - stt['cocodc'] / stt['streaming']):.1f}%")
    routed_failures = compare_routed(payloads)
    if args.smoke:
        failures.extend(routed_failures)
    for f in routed_failures:
        print(f"ROUTED COMPARE FAIL {f}", file=sys.stderr, flush=True)
    save_json("sweep_summary", summary)
    if failures:
        print(f"{len(failures)} failure(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
