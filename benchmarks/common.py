"""Shared helpers for the benchmark harness."""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload):
    """`name` may carry subdirectories (e.g. "sweep/hub_failure8")."""
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
